"""Differential suite for segment merge + the generational (LSM) index.

The contract is the strongest one available: ``merge(build(A), build(B))`` must
be *bit-identical* -- every pytree leaf -- to ``build(A ∪ B)`` (dedup-summed
union), for both layouts and both merge routes, because ``index_from_segment``
is shared and the continuation order is a pure function of the row set.  On
top: the uint32 overflow guard trips loudly, the generational index answers
queries over >=3 ingests (with compactions) exactly like a from-scratch build,
and the streaming-serving pieces (LRU cache, double-buffered driver) behave.

Corpus generation is hypothesis-driven where available and degrades to the
same generator over fixed parametrized draws without it (repo convention).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

import jax

from repro.core import oracle, run_job
from repro.core.stats import NGramConfig, NGramStats
from repro.index import (GenerationalIndex, build_compressed_index,
                         build_index, continuations, generational_from_stats,
                         lookup, merge_indexes, merge_segments,
                         segment_to_stats, stats_union)
from repro.index.build import IndexSegment, segment_from_stats
from tests.test_compress import make_corpus


def assert_trees_equal(a, b):
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def job_pair(vocab, dist, sigma, tau, seed, n=2500):
    cfg = NGramConfig(sigma=sigma, tau=tau, vocab_size=vocab)
    sa = run_job(make_corpus(n, vocab, dist, seed), cfg)
    sb = run_job(make_corpus(n, vocab, dist, seed + 1000), cfg)
    return sa, sb


def check_merge_parity(sa, sb, vocab, *, block=4):
    union = stats_union(sa, sb)
    # flat: both routes, ref and kernel merge-path
    want = build_index(union, vocab_size=vocab)
    for kw in (dict(route="merge"), dict(route="merge", use_kernels=True),
               dict(route="sort"), dict(route="device")):
        got = merge_indexes([build_index(sa, vocab_size=vocab),
                             build_index(sb, vocab_size=vocab)], **kw)
        assert_trees_equal(got, want)
    # compressed layout, same bar
    cwant = build_compressed_index(union, vocab_size=vocab, block_size=block)
    cgot = merge_indexes(
        [build_compressed_index(sa, vocab_size=vocab, block_size=block),
         build_compressed_index(sb, vocab_size=vocab, block_size=block)])
    assert_trees_equal(cgot, cwant)


MERGE_DRAWS = [  # (vocab, dist, sigma, tau, seed)
    (5, "uniform", 3, 1, 0),
    (40, "zipf", 5, 2, 1),
    (700, "uniform", 4, 1, 2),
    (5000, "zipf", 4, 2, 3),
]


@pytest.mark.parametrize("vocab,dist,sigma,tau,seed", MERGE_DRAWS)
def test_merge_parity_generated_corpora(vocab, dist, sigma, tau, seed):
    sa, sb = job_pair(vocab, dist, sigma, tau, seed)
    check_merge_parity(sa, sb, vocab)


def test_kway_merge_and_edge_segments():
    """3-way merge == union build; empty and singleton segments fold away."""
    vocab = 30
    cfg = NGramConfig(sigma=3, tau=1, vocab_size=vocab)
    stats = [run_job(make_corpus(800, vocab, "zipf", s), cfg)
             for s in range(3)]
    empty = NGramStats(np.zeros((0, 3), np.int32), np.zeros(0, np.int32),
                       np.zeros(0, np.int64))
    ixs = [build_index(s, vocab_size=vocab) for s in stats]
    ixs.append(build_index(empty, vocab_size=vocab))
    want = build_index(stats_union(*stats), vocab_size=vocab)
    for kw in (dict(route="merge"), dict(route="sort")):
        assert_trees_equal(merge_indexes(ixs, **kw), want)


def test_merge_validation_errors():
    a = segment_from_stats(NGramStats(np.array([[1, 0]], np.int32),
                                      np.array([1], np.int32),
                                      np.array([3], np.int64)), vocab_size=9)
    b = segment_from_stats(NGramStats(np.array([[1, 0, 0]], np.int32),
                                      np.array([1], np.int32),
                                      np.array([3], np.int64)), vocab_size=9)
    with pytest.raises(ValueError):
        merge_segments([])
    with pytest.raises(ValueError):
        merge_segments([a, b])             # sigma mismatch
    with pytest.raises(ValueError):
        merge_segments([a], route="bogus")
    s = NGramStats(np.array([[1, 0]], np.int32), np.array([1], np.int32),
                   np.array([3], np.int64))
    with pytest.raises(ValueError):        # mixed layouts
        merge_indexes([build_index(s, vocab_size=9),
                       build_compressed_index(s, vocab_size=9)])


def test_merged_count_overflow_guard_trips():
    """Summed uint32 counts past 2^32 must refuse loudly, not wrap."""
    big = 2**31 + 5                        # fits uint32 alone, wraps summed
    mk = lambda: NGramStats(np.array([[7, 0, 0]], np.int32),
                            np.array([1], np.int32),
                            np.array([big], np.int64))
    segs = [segment_from_stats(mk(), vocab_size=9) for _ in range(2)]
    for kw in (dict(route="merge"), dict(route="sort")):
        with pytest.raises(ValueError, match="overflow"):
            merge_segments(segs, **kw)
    # just-below-the-edge sums must still merge exactly
    small = NGramStats(np.array([[7, 0, 0]], np.int32),
                       np.array([1], np.int32), np.array([10], np.int64))
    seg = merge_segments([segs[0], segment_from_stats(small, vocab_size=9)])
    assert np.asarray(seg.counts)[0] == np.uint32(big + 10)


def test_device_fold_host_fallback_parity(monkeypatch):
    """Runs longer than the two-limb device budget must replay on the host
    with identical output: force the fallback by shrinking the threshold and
    compare whole segments against the device fold."""
    from repro.index import merge as merge_mod

    sa, sb = job_pair(40, "zipf", 4, 2, seed=3, n=1500)
    segs = [segment_from_stats(s, vocab_size=40) for s in (sa, sb)]
    want = merge_segments(segs)                        # device fold
    monkeypatch.setattr(merge_mod, "_MAX_DEVICE_RUN", 1)
    got = merge_segments(segs)                         # host replay
    np.testing.assert_array_equal(np.asarray(got.keys), np.asarray(want.keys))
    np.testing.assert_array_equal(np.asarray(got.counts),
                                  np.asarray(want.counts))


def test_device_merge_route_oversized_falls_back_to_kway(monkeypatch):
    """The ``device`` route's size guard: above ``DEVICE_MERGE_MAX_ROWS``
    total input rows the fold must silently reroute to the galloping host
    k-way merge with identical output (the oversized tau=1 gram-set case the
    mesh wave accumulator hits)."""
    from repro.index import merge as merge_mod

    vocab = 30
    cfg = NGramConfig(sigma=3, tau=1, vocab_size=vocab)
    stats = [run_job(make_corpus(900, vocab, "zipf", s), cfg)
             for s in range(3)]
    segs = [segment_from_stats(s, vocab_size=vocab) for s in stats]
    want = merge_segments(segs, route="kway")
    on_device = merge_segments(segs, route="device")
    monkeypatch.setattr(merge_mod, "DEVICE_MERGE_MAX_ROWS", 1)
    fell_back = merge_segments(segs, route="device")
    for got in (on_device, fell_back):
        np.testing.assert_array_equal(np.asarray(got.keys),
                                      np.asarray(want.keys))
        np.testing.assert_array_equal(np.asarray(got.counts),
                                      np.asarray(want.counts))


def test_generational_query_overflow_guard_trips():
    """Counts split across live segments must not silently wrap at query time
    (the lookup-side mirror of the merge fold's guard)."""
    big = 2**31 + 5
    mk = lambda seed: NGramStats(np.array([[7, 0, 0]], np.int32),
                                 np.array([1], np.int32),
                                 np.array([big], np.int64))
    gen = GenerationalIndex(sigma=3, vocab_size=9, size_ratio=1)
    gen.levels = [build_index(mk(0), vocab_size=9),
                  build_index(mk(1), vocab_size=9)]   # bypass compaction
    g = np.array([[7, 0, 0]], np.int32)
    ln = np.array([1], np.int32)
    with pytest.raises(ValueError, match="overflow"):
        lookup(gen, g, ln)
    with pytest.raises(ValueError, match="overflow"):
        continuations(gen, np.zeros((1, 3), np.int32),
                      np.zeros(1, np.int32), k=2)


def test_segment_round_trips():
    """to_segment() of both layouts reproduces the built segment bit-exactly."""
    toks = make_corpus(3000, 50, "zipf", 4)
    stats = run_job(toks, NGramConfig(sigma=4, tau=2, vocab_size=50))
    seg = segment_from_stats(stats, vocab_size=50)
    idx = build_index(stats, vocab_size=50)
    assert_trees_equal(idx.to_segment(), seg)
    cidx = build_compressed_index(stats, vocab_size=50)
    assert_trees_equal(cidx.to_segment(), seg)
    # and stats survive the segment view (dict equality; row order may differ)
    assert segment_to_stats(seg).to_dict() == stats.to_dict()


# --------------------------------------------------------------------------- #
# compressed-native merge (streamed block decode)
# --------------------------------------------------------------------------- #

def test_compressed_native_merge_edge_segments():
    """Empty, singleton, and partial-final-block compressed inputs all merge
    bit-identically to the union build through the streamed decode."""
    vocab, sigma = 30, 3
    cfg = NGramConfig(sigma=sigma, tau=1, vocab_size=vocab)
    empty = NGramStats(np.zeros((0, sigma), np.int32), np.zeros(0, np.int32),
                       np.zeros(0, np.int64))
    single = NGramStats(np.array([[5, 0, 0]], np.int32),
                        np.array([1], np.int32), np.array([7], np.int64))
    big = run_job(make_corpus(900, vocab, "zipf", 5), cfg)
    block = 64                              # row counts below won't divide it
    assert build_compressed_index(big, vocab_size=vocab,
                                  block_size=block).n_rows % block != 0
    for parts in ([empty, big], [single, big], [empty, single, big]):
        want = build_compressed_index(stats_union(*parts), vocab_size=vocab,
                                      block_size=block)
        for route in ("kway", "merge"):
            got = merge_indexes(
                [build_compressed_index(s, vocab_size=vocab, block_size=block)
                 for s in parts], route=route)
            assert_trees_equal(got, want)


def test_compressed_native_merge_overflow_guard():
    """The uint32 fold guard fires through the compressed-native path too."""
    big = 2**31 + 5
    mk = lambda: NGramStats(np.array([[7, 0, 0]], np.int32),
                            np.array([1], np.int32),
                            np.array([big], np.int64))
    cixs = [build_compressed_index(mk(), vocab_size=9) for _ in range(2)]
    for route in ("kway", "merge"):
        with pytest.raises(ValueError, match="overflow"):
            merge_indexes(cixs, route=route)


def test_compressed_merge_working_set_is_block_batches(monkeypatch):
    """Compaction must never materialize a whole decoded table: with the chunk
    shrunk to 64 rows, the decode high-water mark stays at the chunk size while
    merging inputs hundreds of rows deep -- and the output is still exact."""
    from repro.index import compress as compress_mod

    vocab = 40
    sa, sb = job_pair(vocab, "zipf", 4, 1, seed=21, n=3000)
    ca, cb = (build_compressed_index(s, vocab_size=vocab) for s in (sa, sb))
    assert min(ca.n_rows, cb.n_rows) > 64   # inputs dwarf the chunk
    monkeypatch.setattr(compress_mod, "_DECODE_CHUNK_ROWS", 64)
    monkeypatch.setitem(compress_mod._DECODE_WATERMARK, "rows", 0)
    got = merge_indexes([ca, cb], route="kway")
    peak = compress_mod._DECODE_WATERMARK["rows"]
    assert 0 < peak <= 64                   # O(block batch), not O(table)
    want = build_compressed_index(stats_union(sa, sb), vocab_size=vocab)
    assert_trees_equal(got, want)


def test_decode_segment_chunk_sweep():
    """decode_segment is chunk-size invariant and equals the unpadded truth."""
    from repro.index.compress import decode_segment
    # tiny corpus: chunk=1 walks every row in its own dispatch round, so the
    # sweep cost is n_rows * n_chunk_sizes host round-trips -- keep rows low
    vocab = 20
    stats = run_job(make_corpus(200, vocab, "zipf", 9),
                    NGramConfig(sigma=3, tau=1, vocab_size=vocab))
    seg = segment_from_stats(stats, vocab_size=vocab)
    r = seg.n_rows
    cidx = build_compressed_index(stats, vocab_size=vocab, block_size=4)
    for chunk in (1, 3, 64, 10**9):
        got = decode_segment(cidx, chunk_rows=chunk)
        assert got.n_rows == r == int(got.keys.shape[0])   # unpadded
        np.testing.assert_array_equal(np.asarray(got.keys),
                                      np.asarray(seg.keys)[:r])
        np.testing.assert_array_equal(np.asarray(got.counts),
                                      np.asarray(seg.counts)[:r])


if HAS_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(vocab=st.integers(2, 5000),
           dist=st.sampled_from(["zipf", "uniform"]),
           sigma=st.integers(1, 6), tau=st.integers(1, 3),
           seed=st.integers(0, 2**16))
    def test_merge_parity_hypothesis(vocab, dist, sigma, tau, seed):
        sa, sb = job_pair(vocab, dist, sigma, tau, seed, n=1500)
        check_merge_parity(sa, sb, vocab)


# --------------------------------------------------------------------------- #
# generational index
# --------------------------------------------------------------------------- #

def drive_generational(compress: bool):
    """>=3 ingests with at least one compaction; parity vs from-scratch."""
    vocab, sigma, tau = 40, 4, 1
    cfg = NGramConfig(sigma=sigma, tau=tau, vocab_size=vocab)
    slices = [make_corpus(n, vocab, "zipf", 10 + i)
              for i, n in enumerate((4000, 900, 900, 900))]
    all_stats = [run_job(t, cfg) for t in slices]
    gen = GenerationalIndex(sigma=sigma, vocab_size=vocab, compress=compress)
    merges = 0
    for s in all_stats:
        merges += gen.ingest(s)["merges"]
    assert merges >= 1                     # the policy actually compacted
    assert gen.n_segments >= 2             # ...but not down to one artifact
    union = stats_union(*all_stats)
    build = build_compressed_index if compress else build_index
    target = build(union, vocab_size=vocab)

    exp = union.to_dict()
    gram_tuples = sorted(exp)
    g = np.zeros((len(gram_tuples), sigma), np.int32)
    ln = np.zeros(len(gram_tuples), np.int32)
    for i, t in enumerate(gram_tuples):
        g[i, :len(t)] = t
        ln[i] = len(t)
    got = np.asarray(lookup(gen, g, ln))
    np.testing.assert_array_equal(got, np.asarray(lookup(target, g, ln)))
    np.testing.assert_array_equal(got, [exp[t] for t in gram_tuples])

    rng = np.random.default_rng(0)
    lm = rng.integers(1, sigma + 1, 1500).astype(np.int32)
    gm = rng.integers(1, vocab + 1, (1500, sigma)).astype(np.int32)
    gm *= np.arange(sigma)[None, :] < lm[:, None]
    np.testing.assert_array_equal(np.asarray(lookup(gen, gm, lm)),
                                  np.asarray(lookup(target, gm, lm)))

    pool = [t[:-1] for t in gram_tuples if len(t) >= 2]
    prefixes = [(), ()] + [pool[i] for i in rng.choice(len(pool), 25)] \
        + [(vocab + 2,)]
    pg = np.zeros((len(prefixes), sigma), np.int32)
    pl = np.zeros(len(prefixes), np.int32)
    for i, t in enumerate(prefixes):
        pg[i, :len(t)] = t
        pl[i] = len(t)
    for uk in (False, True):
        got_c = [np.asarray(x) for x in
                 continuations(gen, pg, pl, k=6, use_kernels=uk)]
        want_c = [np.asarray(x) for x in continuations(target, pg, pl, k=6)]
        for a, b in zip(got_c, want_c):
            np.testing.assert_array_equal(a, b)

    # compact_all collapses to one segment with the same (bit-exact) artifact
    gen.compact_all()
    assert gen.n_segments == 1
    assert_trees_equal(gen.segments[0], target)


def test_generational_flat():
    drive_generational(compress=False)


def test_generational_compressed():
    drive_generational(compress=True)


def test_generational_tier_policy_keeps_l0_flat():
    """Fresh ingests stay flat (hot L0); only merged rungs freeze compressed."""
    from repro.index.build import NGramIndex
    from repro.index.compress import CompressedNGramIndex
    vocab, sigma = 40, 4
    cfg = NGramConfig(sigma=sigma, tau=1, vocab_size=vocab)
    gen = GenerationalIndex(sigma=sigma, vocab_size=vocab, compress=True)
    merges = 0
    for i, n in enumerate((4000, 900, 900, 900)):
        merges += gen.ingest(run_job(make_corpus(n, vocab, "zipf", 10 + i),
                                     cfg))["merges"]
    assert merges >= 1
    kinds = [type(ix) for ix in gen.segments]
    assert kinds[0] is NGramIndex           # newest rung: hot, flat
    assert CompressedNGramIndex in kinds    # elder rung(s): frozen compressed
    # compressed segments + bytes at rest are what the gauges report
    n_c = sum(k is CompressedNGramIndex for k in kinds)
    at_rest = sum(getattr(ix, "nbytes_at_rest", None) or ix.nbytes
                  for ix in gen.segments)
    assert n_c >= 1 and 0 < at_rest < sum(ix.nbytes for ix in gen.segments)


def check_mixed_stack_parity(sa, sb, vocab, sigma, *, block=4):
    """A stack mixing flat and compressed rungs answers bit-identically to the
    all-flat stack -- the compressed-at-rest serving contract."""
    ia, ib = (build_index(s, vocab_size=vocab) for s in (sa, sb))
    ca = build_compressed_index(sa, vocab_size=vocab, block_size=block)
    flat = GenerationalIndex(sigma=sigma, vocab_size=vocab)
    flat.levels = [ib, ia]                  # newest first, elder flat
    mixed = GenerationalIndex(sigma=sigma, vocab_size=vocab)
    mixed.levels = [ib, ca]                 # same rows, elder frozen

    exp = stats_union(sa, sb).to_dict()
    rng = np.random.default_rng(11)
    all_tuples = sorted(exp)
    gram_tuples = [all_tuples[i] for i in sorted(
        rng.choice(len(all_tuples), min(len(all_tuples), 500), replace=False))]
    miss_g = rng.integers(1, vocab + 1, (150, sigma)).astype(np.int32)
    miss_l = rng.integers(1, sigma + 1, 150).astype(np.int32)
    miss_g *= np.arange(sigma)[None, :] < miss_l[:, None]
    g = np.zeros((len(gram_tuples) + 150, sigma), np.int32)
    ln = np.zeros(len(gram_tuples) + 150, np.int32)
    for i, t in enumerate(gram_tuples):
        g[i, :len(t)] = t
        ln[i] = len(t)
    g[len(gram_tuples):] = miss_g
    ln[len(gram_tuples):] = miss_l
    got = np.asarray(lookup(mixed, g, ln))
    np.testing.assert_array_equal(got, np.asarray(lookup(flat, g, ln)))
    np.testing.assert_array_equal(
        got[:len(gram_tuples)], [exp[t] for t in gram_tuples])

    pool = [t[:-1] for t in all_tuples if len(t) >= 2] or [()]
    prefixes = [(), (vocab + 2,)] + [pool[i]
                                     for i in rng.choice(len(pool), 10)]
    pg = np.zeros((len(prefixes), sigma), np.int32)
    pl = np.zeros(len(prefixes), np.int32)
    for i, t in enumerate(prefixes):
        pg[i, :len(t)] = t
        pl[i] = len(t)
    got_c = [np.asarray(x) for x in continuations(mixed, pg, pl, k=5)]
    want_c = [np.asarray(x) for x in continuations(flat, pg, pl, k=5)]
    for a, b in zip(got_c, want_c):
        np.testing.assert_array_equal(a, b)


# two draws, not all of MERGE_DRAWS: every (vocab, sigma) pair recompiles the
# whole compressed query stack, and the hypothesis tier below varies them too
@pytest.mark.parametrize("vocab,dist,sigma,tau,seed",
                         [MERGE_DRAWS[1], MERGE_DRAWS[3]])
def test_mixed_stack_parity_generated_corpora(vocab, dist, sigma, tau, seed):
    sa, sb = job_pair(vocab, dist, sigma, tau, seed, n=1500)
    check_mixed_stack_parity(sa, sb, vocab, sigma)


if HAS_HYPOTHESIS:
    @pytest.mark.slow
    @settings(max_examples=4, deadline=None)
    @given(vocab=st.integers(2, 5000),
           dist=st.sampled_from(["zipf", "uniform"]),
           sigma=st.integers(1, 6), tau=st.integers(1, 3),
           seed=st.integers(0, 2**16))
    def test_mixed_stack_parity_hypothesis(vocab, dist, sigma, tau, seed):
        sa, sb = job_pair(vocab, dist, sigma, tau, seed, n=1200)
        check_mixed_stack_parity(sa, sb, vocab, sigma)


def test_generational_bootstrap_and_empty():
    empty = GenerationalIndex(sigma=3, vocab_size=9)
    assert np.asarray(lookup(empty, np.zeros((2, 3), np.int32),
                             np.ones(2, np.int32))).tolist() == [0, 0]
    nd, tot, terms, cfs = continuations(empty, np.zeros((2, 3), np.int32),
                                        np.zeros(2, np.int32), k=4)
    assert np.asarray(nd).tolist() == [0, 0]
    s = NGramStats(np.array([[5, 0, 0]], np.int32), np.array([1], np.int32),
                   np.array([7], np.int64))
    gen = generational_from_stats(s, vocab_size=9)
    assert gen.n_segments == 1 and gen.generation == 1
    with pytest.raises(ValueError):        # sigma mismatch on ingest
        gen.ingest(NGramStats(np.zeros((0, 4), np.int32),
                              np.zeros(0, np.int32), np.zeros(0, np.int64)))


# --------------------------------------------------------------------------- #
# streaming serving pieces (LRU cache, double buffering)
# --------------------------------------------------------------------------- #

def test_lru_cache_eviction_and_invalidation():
    from repro.launch.serve_ngrams import LRUQueryCache
    c = LRUQueryCache(capacity=2)
    c.put("a", 1, 10)
    c.put("b", 1, 20)
    assert c.get("a", 1) == 10             # refreshes "a"
    c.put("x", 1, 30)                      # evicts LRU "b"
    assert c.get("b", 1) is None
    assert c.get("a", 1) == 10 and c.get("x", 1) == 30
    assert c.get("a", 2) is None           # generation swap drops everything
    assert len(c) == 0
    c.put("a", 2, 11)
    assert c.get("a", 2) == 11
    assert 0.0 < c.hit_rate < 1.0
    # a stale (pre-swap) writer must neither install nor roll the cache back
    c.put("old", 1, 99)
    assert c.generation == 2 and c.get("a", 2) == 11
    assert c.get("old", 2) is None
    assert c.get("a", 1) is None           # stale reader: miss, no clear
    assert c.get("a", 2) == 11


def test_streaming_service_matches_oracle_and_caches():
    from repro.launch.serve_ngrams import StreamingNGramService
    vocab, sigma = 30, 3
    cfg = NGramConfig(sigma=sigma, tau=1, vocab_size=vocab)
    svc = StreamingNGramService(cfg, cache_capacity=4096)
    slices = [make_corpus(700, vocab, "zipf", 30 + i) for i in range(3)]
    for t in slices:
        svc.ingest(t)
    exp = stats_union(*[run_job(t, cfg) for t in slices]).to_dict()
    gram_tuples = sorted(exp)
    g = np.zeros((len(gram_tuples), sigma), np.int32)
    ln = np.zeros(len(gram_tuples), np.int32)
    for i, t in enumerate(gram_tuples):
        g[i, :len(t)] = t
        ln[i] = len(t)
    got = svc.lookup(g, ln)
    np.testing.assert_array_equal(got, [exp[t] for t in gram_tuples])
    # a repeat is pure cache: hits grow by the batch, misses don't
    h0, m0 = svc.cache.hits, svc.cache.misses
    again = svc.lookup(g, ln)
    np.testing.assert_array_equal(again, got)
    assert svc.cache.hits == h0 + len(gram_tuples)
    assert svc.cache.misses == m0
    # pipelined (double-buffered) drive returns the same answers in order
    batches = [(g[i:i + 64], ln[i:i + 64]) for i in range(0, len(gram_tuples), 64)]
    outs = svc.lookup_pipelined(batches)
    np.testing.assert_array_equal(np.concatenate(outs), got)
    # ingest bumps the generation -> stale entries never served
    svc.ingest(make_corpus(700, vocab, "zipf", 77))
    fresh = svc.lookup(g, ln)
    exp2 = stats_union(*[run_job(t, cfg) for t in slices +
                         [make_corpus(700, vocab, "zipf", 77)]]).to_dict()
    np.testing.assert_array_equal(fresh,
                                  [exp2[t] for t in gram_tuples])
    # top-k through the service agrees with the generational query path
    pool = [t[:-1] for t in gram_tuples if len(t) >= 2][:10]
    pg = np.zeros((len(pool), sigma), np.int32)
    pl = np.zeros(len(pool), np.int32)
    for i, t in enumerate(pool):
        pg[i, :len(t)] = t
        pl[i] = len(t)
    rows = svc.continuations(pg, pl, k=4)
    nd, tot, terms, cfs = [np.asarray(x)
                           for x in continuations(svc.gen, pg, pl, k=4)]
    np.testing.assert_array_equal(rows[:, 0], nd)
    np.testing.assert_array_equal(rows[:, 2:6], terms)
    np.testing.assert_array_equal(rows[:, 6:], cfs)


def test_double_buffered_driver_orders_results():
    from repro.launch.serve_ngrams import DoubleBufferedDriver
    calls = []
    drv = DoubleBufferedDriver(lambda x: (calls.append(x), x * 2)[1])
    outs = []
    for i in range(4):
        res, tag = drv.submit(np.asarray([i]), tag=i)
        if res is not None:
            outs.append((int(res[0]), tag))
    res, tag = drv.drain()
    outs.append((int(res[0]), tag))
    assert outs == [(0, 0), (2, 1), (4, 2), (6, 3)]
    assert drv.drain() == (None, None)
