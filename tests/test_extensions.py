"""Property tests for SSVI maximal/closed filtering (``core/extensions.py``).

``filter_stats`` implements the paper's two-stage one-term-extension scheme
(right extensions on the forward grams, left extensions on the reversed
survivors); the oracle's ``maximal_ngrams`` / ``closed_ngrams`` check *every*
contiguous supersequence in O(n^2).  The APRIORI argument says they agree --
these tests make that an executed property over random corpora rather than a
comment, since a filtering bug silently shrinks or inflates reported result
sets (Fig. 2's headline numbers) without failing any counting test.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.core import oracle, run_job
from repro.core.extensions import filter_stats
from repro.core.stats import NGramConfig


def _check(toks, sigma, tau, vocab):
    stats = run_job(np.asarray(toks, np.int64),
                    NGramConfig(sigma=sigma, tau=tau, vocab_size=vocab))
    exp = oracle.ngram_counts(toks, sigma, tau)
    assert stats.to_dict() == exp
    got_max = filter_stats(stats, "max").to_dict()
    assert got_max == oracle.maximal_ngrams(exp)
    got_closed = filter_stats(stats, "closed").to_dict()
    assert got_closed == oracle.closed_ngrams(exp)
    # closedness is weaker than maximality: every maximal gram is closed
    assert set(got_max) <= set(got_closed)


@pytest.mark.parametrize("seed,vocab,sigma,tau,n", [
    (0, 4, 3, 2, 400),       # tiny vocab -> dense extension structure
    (1, 12, 4, 2, 600),
    (2, 30, 5, 3, 800),
    (3, 2, 4, 1, 200),       # tau=1: everything frequent, worst-case overlap
    (4, 50, 3, 4, 1000),
])
def test_filter_stats_matches_bruteforce(seed, vocab, sigma, tau, n):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, vocab + 1, n)          # 0s = sentence separators
    _check(toks, sigma, tau, vocab)


def test_filter_stats_handcrafted_runs():
    # "1 2 3" repeated: every proper sub-gram has a frequent extension with the
    # same count, so only the full window survives either filter
    toks = np.array(([1, 2, 3] * 10 + [0]) * 3).ravel()
    stats = run_job(toks, NGramConfig(sigma=3, tau=2, vocab_size=3))
    exp = oracle.ngram_counts(toks, 3, 2)
    got = filter_stats(stats, "closed").to_dict()
    assert got == oracle.closed_ngrams(exp)
    assert (1, 2, 3) in got and (1, 2) not in got


if HAS_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31), vocab=st.integers(1, 40),
           sigma=st.integers(1, 5), tau=st.integers(1, 4),
           n=st.integers(10, 600))
    def test_filter_stats_matches_bruteforce_fuzzed(seed, vocab, sigma, tau, n):
        rng = np.random.default_rng(seed)
        toks = rng.integers(0, vocab + 1, n)
        _check(toks, sigma, tau, vocab)
