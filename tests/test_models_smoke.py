"""Per-architecture smoke tests: REDUCED config, one forward/train step on CPU,
output shapes + finiteness.  The FULL configs are exercised only by the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data import graph as gdata, recsys as rdata

LM_ARCHS = ["deepseek-moe-16b", "mixtral-8x7b", "minicpm3-4b", "phi3-medium-14b",
            "llama3.2-1b"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_reduced_train_step(arch):
    from repro.models import transformer as tf
    from repro.training.optimizer import OptimizerConfig, init_state
    from repro.training.train_loop import make_train_step

    cfg = configs.get(arch).make_reduced()
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 1, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    step = jax.jit(make_train_step(lambda p, b: tf.loss_fn(p, b, cfg),
                                   OptimizerConfig(warmup_steps=1, decay_steps=10)))
    params, opt, metrics = step(params, init_state(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_reduced_prefill_decode(arch):
    from repro.models import transformer as tf

    cfg = configs.get(arch).make_reduced()
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 1, cfg.vocab_size)
    cache, logits = tf.prefill(params, toks, cfg, max_seq=16)
    assert logits.shape == (2, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    logits2, cache = tf.decode_step(params, cache, toks[:, -1], jnp.int32(12), cfg)
    assert logits2.shape == (2, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2)).all()


def test_gin_reduced_step():
    from repro.models import gnn
    from repro.training.optimizer import OptimizerConfig, init_state
    from repro.training.train_loop import make_train_step

    cfg = configs.get("gin-tu").make_reduced()
    g = gdata.random_graph(40, 160, cfg.d_feat, cfg.n_classes, seed=0)
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"features": jnp.asarray(g.features),
             "edge_src": jnp.asarray(g.edge_index[0]),
             "edge_dst": jnp.asarray(g.edge_index[1]),
             "labels": jnp.asarray(g.labels)}
    step = jax.jit(make_train_step(lambda p, b: gnn.loss_fn(p, b, cfg),
                                   OptimizerConfig(warmup_steps=1, decay_steps=10)))
    params, opt, metrics = step(params, init_state(params), batch)
    assert np.isfinite(float(metrics["loss"]))


def test_gin_neighbor_sampler_step():
    from repro.models import gnn

    cfg = configs.get("gin-tu").make_reduced()
    g = gdata.random_graph(300, 2000, cfg.d_feat, cfg.n_classes, seed=1)
    table = gdata.CSRNeighborTable(g)
    sub = gdata.sample_subgraph(g, table, np.arange(16), (5, 3), seed=2)
    n_sub = sub.features.shape[0]
    assert n_sub == 16 + 16 * 5 + 16 * 5 * 3
    batch = {"features": jnp.asarray(sub.features),
             "edge_src": jnp.asarray(sub.edge_src),
             "edge_dst": jnp.asarray(sub.edge_dst),
             "edge_mask": jnp.asarray(sub.edge_mask),
             "labels": jnp.pad(jnp.asarray(sub.labels), (0, n_sub - sub.n_seeds)),
             "label_mask": jnp.arange(n_sub) < sub.n_seeds}
    loss, _ = gnn.loss_fn(gnn.init_params(jax.random.PRNGKey(0), cfg), batch, cfg)
    assert np.isfinite(float(loss))


RECSYS = ["bst", "autoint", "two-tower-retrieval", "xdeepfm"]


@pytest.mark.parametrize("arch", RECSYS)
def test_recsys_reduced_step(arch):
    from repro.models import recsys as R
    from repro.training.optimizer import OptimizerConfig, init_state
    from repro.training.train_loop import make_train_step

    cfg = configs.get(arch).make_reduced()
    key = jax.random.PRNGKey(0)
    b = 8
    if arch == "bst":
        params = R.bst_init(key, cfg)
        batch = rdata.BehaviorSeqGen(cfg.item_vocab, cfg.seq_len).batch_at(0, b)
        loss = lambda p, bt: R.bst_loss(p, bt, cfg)
    elif arch == "autoint":
        params = R.autoint_init(key, cfg)
        batch = rdata.CTRBatchGen((cfg.field_vocab,) * cfg.n_sparse).batch_at(0, b)
        loss = lambda p, bt: R.autoint_loss(p, bt, cfg)
    elif arch == "two-tower-retrieval":
        params = R.twotower_init(key, cfg)
        batch = rdata.RetrievalGen(cfg.item_vocab, cfg.user_feat).batch_at(0, b)
        loss = lambda p, bt: R.twotower_loss(p, bt, cfg)
    else:
        params = R.xdeepfm_init(key, cfg)
        batch = rdata.CTRBatchGen((cfg.field_vocab,) * cfg.n_sparse).batch_at(0, b)
        loss = lambda p, bt: R.xdeepfm_loss(p, bt, cfg)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    step = jax.jit(make_train_step(loss, OptimizerConfig(warmup_steps=1,
                                                         decay_steps=10)))
    params, opt, metrics = step(params, init_state(params), batch)
    assert np.isfinite(float(metrics["loss"]))


def test_twotower_candidate_scoring():
    from repro.models import recsys as R
    cfg = configs.get("two-tower-retrieval").make_reduced()
    p = R.twotower_init(jax.random.PRNGKey(0), cfg)
    scores = R.twotower_score_candidates(
        p, {"user": jnp.ones((1, cfg.user_feat)),
            "candidates": jnp.arange(64, dtype=jnp.int32)}, cfg)
    assert scores.shape == (1, 64)
    assert np.isfinite(np.asarray(scores)).all()


def test_embedding_bag_modes():
    from repro.models.recsys import embedding_bag
    table = jnp.asarray(np.arange(20, dtype=np.float32).reshape(10, 2))
    ids = jnp.asarray([0, 1, 2, 5])
    seg = jnp.asarray([0, 0, 1, 1])
    s = embedding_bag(table, ids, seg, 2, "sum")
    np.testing.assert_allclose(np.asarray(s), [[2, 4], [14, 16]])
    m = embedding_bag(table, ids, seg, 2, "mean")
    np.testing.assert_allclose(np.asarray(m), [[1, 2], [7, 8]])
    mx = embedding_bag(table, ids, seg, 2, "max")
    np.testing.assert_allclose(np.asarray(mx), [[2, 3], [10, 11]])


def test_all_40_cells_enumerate():
    cells = [(a, s) for a in configs.ASSIGNED for s in configs.get(a).shapes]
    assert len(cells) == 40
    skips = [c for a, s in cells
             if (c := configs.get(a).shapes[s].skip_reason) is not None]
    assert len(skips) == 4  # the documented full-attention long_500k skips
