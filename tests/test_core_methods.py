"""All four n-gram methods vs the pure-Python oracle, incl. the paper's running
example (SSIII) and its per-method record-count analyses."""
import numpy as np
import pytest

from repro.core import METHODS, NGramConfig, oracle, run_job

# paper running example, a=1 b=2 x=3
D1, D2, D3 = [1, 3, 2, 3, 3], [2, 1, 3, 2, 3], [3, 2, 1, 3, 2]
PAPER = np.asarray(D1 + [0] + D2 + [0] + D3, np.int32)


@pytest.mark.parametrize("method", sorted(METHODS))
def test_paper_running_example(method):
    cfg = NGramConfig(sigma=3, tau=3, vocab_size=3, method=method)
    got = run_job(PAPER, cfg).to_dict()
    assert got == {(1,): 3, (2,): 5, (3,): 7, (1, 3): 3, (3, 2): 4, (1, 3, 2): 3}


@pytest.mark.parametrize("method", sorted(METHODS))
@pytest.mark.parametrize("seed", range(4))
def test_random_corpora_match_oracle(method, seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(20, 400))
    v = int(rng.integers(2, 50))
    toks = rng.integers(0, v + 1, n)
    sigma = int(rng.integers(1, 7))
    tau = int(rng.integers(1, 4))
    cfg = NGramConfig(sigma=sigma, tau=tau, vocab_size=v, method=method,
                      combine=bool(seed % 2), apriori_index_k=1 + seed % 4)
    assert run_job(toks, cfg).to_dict() == oracle.ngram_counts(toks, sigma, tau)


def test_suffix_sigma_record_count_invariant():
    """SSIV: SUFFIX-sigma emits exactly one record per token occurrence,
    independent of sigma and tau."""
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 30, 1000)
    n_tokens = int((toks != 0).sum())
    for sigma in (1, 3, 9):
        for tau in (1, 5):
            st = run_job(toks, NGramConfig(sigma=sigma, tau=tau, vocab_size=29,
                                           combine=False))
            assert st.counters["map_records"] == n_tokens
    assert oracle.expected_map_records(toks, 5, "suffix_sigma") == n_tokens


def test_naive_record_count_matches_analysis():
    """NAIVE emits sum_{s: |s|<=sigma} cf(s) records (SSIII-A)."""
    rng = np.random.default_rng(1)
    toks = rng.integers(0, 20, 500)
    sigma = 4
    st = run_job(toks, NGramConfig(sigma=sigma, tau=1, vocab_size=19,
                                   method="naive"))
    expected = oracle.expected_map_records(toks, sigma, "naive")
    assert st.counters["map_records"] == expected
    # which equals the total collection frequency of all <=sigma-grams
    all_counts = oracle.ngram_counts(toks, sigma, 1)
    assert expected == sum(all_counts.values())


def test_apriori_scan_prunes_vs_naive():
    """Candidate records of APRIORI-SCAN never exceed NAIVE's emissions and the
    number of jobs is bounded by sigma (SSIII-B)."""
    rng = np.random.default_rng(2)
    toks = rng.integers(0, 50, 800)
    sigma, tau = 5, 4
    scan = run_job(toks, NGramConfig(sigma=sigma, tau=tau, vocab_size=49,
                                     method="apriori_scan"))
    naive = run_job(toks, NGramConfig(sigma=sigma, tau=tau, vocab_size=49,
                                      method="naive"))
    assert scan.counters["map_records"] <= naive.counters["map_records"]
    assert scan.counters["jobs"] <= sigma


def test_methods_agree_pairwise():
    rng = np.random.default_rng(3)
    toks = rng.integers(0, 15, 600)
    cfgs = {m: NGramConfig(sigma=5, tau=3, vocab_size=14, method=m) for m in METHODS}
    results = {m: run_job(toks, c).to_dict() for m, c in cfgs.items()}
    base = results.pop("suffix_sigma")
    for m, r in results.items():
        assert r == base, f"{m} disagrees with suffix_sigma"


def test_empty_and_degenerate_inputs():
    cfg = NGramConfig(sigma=3, tau=1, vocab_size=5)
    assert run_job(np.zeros(10, np.int32), cfg).to_dict() == {}
    assert run_job(np.asarray([2], np.int32), cfg).to_dict() == {(2,): 1}
    one = run_job(np.asarray([2, 2, 2], np.int32),
                  NGramConfig(sigma=2, tau=2, vocab_size=5))
    assert one.to_dict() == {(2,): 3, (2, 2): 2}
