"""The paper's use case (b): text analytics -- long maximal n-grams and their
time series (SSVI), i.e. "find recurring fragments of text and how they spread
over time".

    PYTHONPATH=src python examples/text_analytics.py
"""
import numpy as np

from repro.core import NGramConfig, extensions_filter, suffix_sigma
from repro.data import corpus as corpus_mod


def main() -> None:
    # CW-profile corpus with injected duplicated segments (quotations/boilerplate,
    # the long frequent n-grams of the paper's Fig. 2) + per-document years
    tokens, years = corpus_mod.zipf_corpus(
        150_000, corpus_mod.CW, seed=7, duplicate_frac=0.08, with_years=True,
        n_years=8)
    vocab = corpus_mod.CW.vocab_size

    # document splitting at infrequent terms (SSV) -- prunes most of the stream
    tau = 8
    split, removed = corpus_mod.split_at_infrequent(tokens, tau, vocab)
    print(f"document splitting removed {removed}/{tokens.size} occurrences")

    # analytics job: long n-grams, time-series aggregation per year bucket
    cfg = NGramConfig(sigma=30, tau=tau, vocab_size=vocab, n_buckets=8)
    stats = suffix_sigma.run(split, cfg, bucket_ids=years)
    print(f"{len(stats)} n-grams with cf >= {tau} (sigma=30); "
          f"map records = {int(stats.counters['map_records'])}")

    # maximal filter: drop everything subsumed by a longer frequent fragment
    maximal = extensions_filter(stats, "max")
    print(f"maximal n-grams: {len(maximal)}")

    series = maximal.to_series_dict()
    long_frags = sorted((g for g in series if len(g) >= 5),
                        key=lambda g: -int(series[g].sum()))[:5]
    print("\nlongest recurring fragments and their per-year series:")
    for g in long_frags:
        s = series[g]
        print(f"  len={len(g)} cf={int(s.sum())} series={s.tolist()} ids={g[:8]}…")
    if not long_frags:
        print("  (none above length 5 at this scale)")


if __name__ == "__main__":
    main()
