"""Out-of-core n-gram statistics: a corpus bigger than the device budget.

    PYTHONPATH=src python examples/out_of_core.py

The monolithic jobs materialize every map record on the device at once --
O(corpus x sigma) lanes -- so corpus size is capped by accelerator memory.
The wave engine (``repro.pipeline.WaveExecutor``) lifts the cap: the corpus
stays on the host and streams through the jitted map/combine/sort/reduce
pipeline in fixed-size token waves (plus a sigma-1 halo, like the distributed
jobs' ppermute halo), folding per-wave partials through the segment-merge
path.  Here we *pretend* the device only fits ~16k tokens of job state and
run a corpus 6x that:

  * ``run()``    -- the whole job out of core, bit-identical to monolithic;
  * ``run_streaming()`` -- each wave lands as a fresh L0 of a
    ``GenerationalIndex`` (LSM compaction), so the corpus becomes *queryable
    while it is still being ingested* -- the end-to-end path
    ``serve_ngrams --streaming --wave-tokens`` drives.
"""
import time

import numpy as np

from repro.core import NGramConfig, run_job
from repro.data import corpus as corpus_mod
from repro.index import lookup
from repro.pipeline import WaveExecutor

DEVICE_BUDGET_TOKENS = 16_384          # pretend this is all the HBM we have
CORPUS_TOKENS = 6 * DEVICE_BUDGET_TOKENS


def main() -> None:
    prof = corpus_mod.PROFILES["nyt"]
    tokens = corpus_mod.zipf_corpus(CORPUS_TOKENS, prof, seed=0,
                                    duplicate_frac=0.02)
    cfg = NGramConfig(sigma=3, tau=4, vocab_size=prof.vocab_size)
    ex = WaveExecutor(cfg, wave_tokens=DEVICE_BUDGET_TOKENS)

    t0 = time.perf_counter()
    stats = ex.run(tokens)
    dt = time.perf_counter() - t0
    c = stats.counters
    print(f"out-of-core job: {len(tokens)} tokens in {int(c['waves'])} waves "
          f"of <= {DEVICE_BUDGET_TOKENS} -> {len(stats)} frequent grams "
          f"in {dt:.1f}s ({c['map_records']:.0f} map records)")

    # the wave fold is size-tiered (LSM rungs, like the serving index), so
    # merge work amortizes to O(total log waves) instead of re-merging the
    # whole running segment every wave; benchmarks/waves.py measures the
    # pairwise-vs-tiered gap at 16+ waves
    print(f"segment fold work (tiered accumulator): "
          f"{int(c['fold_rows'])} rows through merge_segments")

    # exactness receipt: the monolithic job (which *can* still run at this
    # size on CPU) produces bit-identical output
    mono = run_job(tokens, cfg)
    assert np.array_equal(stats.grams, mono.grams)
    assert np.array_equal(stats.counts, mono.counts)
    print("bit-identical to the monolithic job: OK")

    # streaming: every wave becomes a queryable generation immediately
    cfg1 = NGramConfig(sigma=3, tau=1, vocab_size=prof.vocab_size)
    t0 = time.perf_counter()
    gen, reports = WaveExecutor(cfg1, wave_tokens=DEVICE_BUDGET_TOKENS) \
        .run_streaming(tokens)
    dt = time.perf_counter() - t0
    merges = sum(r["merges"] for r in reports)
    print(f"streaming ingest: {len(reports)} waves -> {gen!r} "
          f"({merges} compaction merges, {dt:.1f}s)")
    top = stats.counts.argmax()
    g = stats.grams[top:top + 1]
    ln = stats.lengths[top:top + 1]
    cf = int(np.asarray(lookup(gen, g, ln))[0])
    print(f"hottest gram {tuple(int(x) for x in g[0, :ln[0]])}: cf={cf} "
          f"served from {gen.n_segments} live segments")
    assert cf == int(stats.counts[top])


if __name__ == "__main__":
    main()
