"""Serve n-gram statistics: freeze a job's output, then query it like a frontend.

    PYTHONPATH=src python examples/query_serving.py

Runs SUFFIX-sigma over a small corpus, freezes the result into the
device-resident index (``repro.index``), and demonstrates the two serving
primitives: batched point-count lookup (with misses) and top-k next-token
completion -- the autocomplete / backoff-LM read path.
"""
import numpy as np

from repro.core import NGramConfig, run_job
from repro.data.tokenizer import TermDictionary, sentences
from repro.index import build_index, continuations, lookup

TEXT = """
the quick brown fox jumps over the lazy dog. the quick brown fox runs over
the sleepy cat. the lazy dog sleeps all day. a quick brown bird watches the
lazy dog. the quick brown fox jumps over the fence. every lazy dog dreams of
the quick brown fox. the cat and the dog chase the quick brown fox.
"""


def main() -> None:
    docs = sentences(TEXT)
    dictionary = TermDictionary.build(docs)
    tokens = dictionary.encode(docs)
    sigma = 4
    cfg = NGramConfig(sigma=sigma, tau=2, vocab_size=dictionary.vocab_size)
    stats = run_job(tokens, cfg)
    idx = build_index(stats, vocab_size=dictionary.vocab_size)
    print(f"froze {len(stats)} frequent n-grams into a "
          f"{idx.nbytes / 1024:.1f} KiB index\n")

    def ids(words: str) -> tuple[int, ...]:
        # unknown words get an out-of-vocab id: the index answers cf=0 (a miss)
        return tuple(dictionary.term_to_id.get(w, dictionary.vocab_size + 1)
                     for w in words.split())

    queries = ["the quick brown fox", "lazy dog", "the fence",
               "purple fox", "dog"]
    grams = np.zeros((len(queries), sigma), np.int32)
    lengths = np.zeros(len(queries), np.int32)
    for i, qt in enumerate(queries):
        g = ids(qt)
        grams[i, :len(g)] = g
        lengths[i] = len(g)
    counts = np.asarray(lookup(idx, grams, lengths))
    print("point lookups (cf=0 -> miss / below tau):")
    for qt, cf in zip(queries, counts):
        print(f"  cf={int(cf)}  {qt!r}")

    prefixes = ["the quick brown", "the", "lazy"]
    k = 3
    pg = np.zeros((len(prefixes), sigma), np.int32)
    pl = np.zeros(len(prefixes), np.int32)
    for i, pt in enumerate(prefixes):
        g = ids(pt)
        pg[i, :len(g)] = g
        pl[i] = len(g)
    nd, total, terms, cnts = [np.asarray(x)
                              for x in continuations(idx, pg, pl, k=k)]
    print(f"\ntop-{k} completions (n_distinct, total mass, then term:cf):")
    for i, pt in enumerate(prefixes):
        comps = [f"{dictionary.decode_gram([t])[0]}:{int(c)}"
                 for t, c in zip(terms[i], cnts[i]) if c > 0]
        print(f"  {pt!r} -> n={int(nd[i])} total={int(total[i])}  "
              + " ".join(comps))


if __name__ == "__main__":
    main()
