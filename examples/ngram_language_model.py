"""End-to-end driver: the paper's use case (a) -- n-gram statistics feeding
language-model training -- then train a ~100M-param LM for a few hundred steps.

Pipeline (all on this host):
  1. synthesize a Zipf corpus (NYT profile) and run SUFFIX-sigma (sigma=5) to get
     collection frequencies -- the statistics a count-based LM / tokenizer needs;
  2. use the unigram statistics to build the frequency-ordered vocabulary (SSV
     sequence encoding) and to drop infrequent-term positions (document splits);
  3. train a ~100M-parameter llama-style model on the encoded stream with the
     production training loop (checkpointing + recovery + straggler log).

    PYTHONPATH=src python examples/ngram_language_model.py --steps 300
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import NGramConfig, run_job
from repro.data import corpus as corpus_mod
from repro.data.loader import LMBatchLoader
from repro.models.transformer import AttentionConfig, LMConfig, init_params, loss_fn
from repro.training.checkpoint import CheckpointManager
from repro.training.fault_tolerance import StragglerDetector, run_with_recovery
from repro.training.optimizer import OptimizerConfig, init_state
from repro.training.train_loop import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tokens", type=int, default=400_000)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/ngram_lm_ckpt")
    args = ap.parse_args()

    # ---- 1. corpus + n-gram statistics (the paper's job) -------------------
    prof = corpus_mod.CorpusProfile("lm", 8192, 1.15, 24, 10)
    stream = corpus_mod.zipf_corpus(args.tokens, prof, seed=0, duplicate_frac=0.02)
    t0 = time.time()
    stats = run_job(stream, NGramConfig(sigma=5, tau=10, vocab_size=prof.vocab_size))
    print(f"SUFFIX-sigma: {len(stats)} n-grams (tau=10, sigma=5) "
          f"in {time.time()-t0:.1f}s; counters="
          f"{({k: int(v) for k, v in stats.counters.items()})}")

    # ---- 2. frequency-ordered vocab from the unigram stats ----------------
    d = stats.to_dict()
    uni = sorted(((g[0], c) for g, c in d.items() if len(g) == 1),
                 key=lambda kv: -kv[1])
    remap = np.zeros(prof.vocab_size + 1, np.int32)
    for new_id, (old_id, _) in enumerate(uni, start=2):
        remap[old_id] = new_id
    vocab_size = len(uni) + 2                      # + PAD-replacement + unk
    encoded = remap[stream]
    encoded = np.where(encoded == 0, 1, encoded)   # infrequent/separator -> unk
    print(f"vocabulary: {vocab_size} frequent terms "
          f"(dropped {prof.vocab_size - len(uni)} infrequent)")

    # ---- 3. ~100M-param LM training ----------------------------------------
    cfg = LMConfig("ngram-lm-100m", n_layers=8, d_model=768, vocab_size=vocab_size,
                   d_ff=3072, attn=AttentionConfig("gqa", 12, 4, 64),
                   dtype=jnp.float32, remat=False, loss_chunks=4)
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model: {n_params/1e6:.1f}M params")

    opt_cfg = OptimizerConfig(peak_lr=3e-4, warmup_steps=20, decay_steps=args.steps)
    raw_step = jax.jit(make_train_step(lambda p, b: loss_fn(p, b, cfg), opt_cfg),
                       donate_argnums=(0, 1))
    loader = LMBatchLoader(encoded, args.seq, args.batch, seed=0)

    def step_fn(state, batch):
        p, o, m = raw_step(state["params"], state["opt"], batch)
        return {"params": p, "opt": o}, m

    ckpt = CheckpointManager(args.ckpt_dir)
    straggler = StragglerDetector()
    t0 = time.time()
    state, history, retries = run_with_recovery(
        n_steps=args.steps, step_fn=step_fn,
        state={"params": params, "opt": init_state(params)},
        batch_fn=lambda s: {k: jnp.asarray(v) for k, v in loader.batch_at(s).items()},
        ckpt=ckpt, ckpt_every=100, straggler=straggler)
    losses = [float(h["loss"]) for h in history]
    for i in list(range(0, len(losses), max(1, len(losses) // 10))) + [-1]:
        print(f"  step {i if i >= 0 else len(losses)-1:5d}  loss {losses[i]:.4f}")
    tok_s = args.steps * args.batch * args.seq / (time.time() - t0)
    print(f"done: loss {losses[0]:.3f} -> {losses[-1]:.3f}, "
          f"{tok_s:,.0f} tok/s, {retries} restarts")
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
