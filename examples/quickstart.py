"""Quickstart: compute n-gram statistics with SUFFIX-sigma on real text.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import NGramConfig, extensions_filter, run_job
from repro.data.tokenizer import TermDictionary, sentences

TEXT = """
to be or not to be that is the question. whether tis nobler in the mind to
suffer the slings and arrows of outrageous fortune. or to take arms against a
sea of troubles and by opposing end them. to die to sleep no more. and by a
sleep to say we end the heartache and the thousand natural shocks that flesh
is heir to. tis a consummation devoutly to be wished. to die to sleep. to
sleep perchance to dream ay theres the rub. for in that sleep of death what
dreams may come when we have shuffled off this mortal coil must give us pause.
to be or not to be is the question asked by many. to be or not to be they say.
"""


def main() -> None:
    docs = sentences(TEXT)
    dictionary = TermDictionary.build(docs)            # ids by descending cf (SSV)
    tokens = dictionary.encode(docs)
    print(f"{len(docs)} sentences, {dictionary.vocab_size} distinct terms, "
          f"{int((tokens != 0).sum())} token occurrences\n")

    cfg = NGramConfig(sigma=6, tau=2, vocab_size=dictionary.vocab_size)
    stats = run_job(tokens, cfg)
    print(f"SUFFIX-sigma found {len(stats)} n-grams with cf >= {cfg.tau}, "
          f"len <= {cfg.sigma}")
    print(f"counters: {({k: int(v) for k, v in stats.counters.items()})}\n")

    print("top n-grams:")
    for gram, cf in sorted(stats.to_dict().items(),
                           key=lambda kv: (-kv[1], -len(kv[0])))[:10]:
        print(f"  cf={cf}  {' '.join(dictionary.decode_gram(gram))}")

    maximal = extensions_filter(stats, "max")
    print(f"\nmaximal n-grams ({len(maximal)} of {len(stats)}):")
    for gram, cf in sorted(maximal.to_dict().items(), key=lambda kv: -kv[1])[:8]:
        print(f"  cf={cf}  {' '.join(dictionary.decode_gram(gram))}")


if __name__ == "__main__":
    main()
