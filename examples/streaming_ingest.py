"""Streaming ingest: keep a served n-gram index fresh without rebuilds.

    PYTHONPATH=src python examples/streaming_ingest.py

Documents arrive in three batches.  Each batch runs through the ordinary
SUFFIX-sigma job phases and lands as a fresh L0 segment of a
``GenerationalIndex`` (LSM-style: immutable sorted segments, size-tiered
merges); point lookups sum evidence across live segments, so counts update the
moment a batch is swapped in -- watch ``cf`` grow for "the quick brown fox"
below.  Queries go through the streaming service's LRU cache, which
invalidates itself on every swap.
"""
import numpy as np

from repro.core import NGramConfig
from repro.data.tokenizer import TermDictionary, sentences
from repro.launch.serve_ngrams import StreamingNGramService

BATCHES = [
    """the quick brown fox jumps over the lazy dog. the quick brown fox runs
    over the sleepy cat. the lazy dog sleeps all day.""",
    """a quick brown bird watches the lazy dog. the quick brown fox jumps over
    the fence. every lazy dog dreams of the quick brown fox.""",
    """the cat and the dog chase the quick brown fox. the quick brown fox
    outruns every lazy dog. the sleepy cat ignores the quick brown fox.""",
]


def main() -> None:
    # one dictionary over the whole stream (a production system would grow it;
    # ids just need to be stable across batches)
    all_docs = sentences(" ".join(BATCHES))
    dictionary = TermDictionary.build(all_docs)
    sigma = 4
    cfg = NGramConfig(sigma=sigma, tau=2, vocab_size=dictionary.vocab_size)
    svc = StreamingNGramService(cfg, cache_capacity=1024)

    def ids(words: str) -> tuple[int, ...]:
        return tuple(dictionary.term_to_id.get(w, dictionary.vocab_size + 1)
                     for w in words.split())

    watch = ["the quick brown fox", "lazy dog", "sleepy cat", "purple fox"]
    grams = np.zeros((len(watch), sigma), np.int32)
    lengths = np.zeros(len(watch), np.int32)
    for i, w in enumerate(watch):
        g = ids(w)
        grams[i, :len(g)] = g
        lengths[i] = len(g)

    for step, text in enumerate(BATCHES):
        tokens = dictionary.encode(sentences(text))
        rep = svc.ingest(tokens)
        counts = svc.lookup(grams, lengths)
        seg = "+".join(str(r) for r in rep["segment_rows"])
        print(f"batch {step}: +{len(tokens)} tokens -> segments [{seg}] "
              f"(merges={rep['merges']})")
        for w, cf in zip(watch, counts):
            print(f"    cf={int(cf)}  {w!r}")

    # the cache serves repeats without touching the device
    svc.lookup(grams, lengths)
    print(f"cache: {len(svc.cache)} entries, hit rate "
          f"{svc.cache.hit_rate:.0%} (invalidated on every swap)")

    k = 3
    prefixes = ["the quick brown", "the"]
    pg = np.zeros((len(prefixes), sigma), np.int32)
    pl = np.zeros(len(prefixes), np.int32)
    for i, p in enumerate(prefixes):
        g = ids(p)
        pg[i, :len(g)] = g
        pl[i] = len(g)
    rows = svc.continuations(pg, pl, k=k)
    print(f"top-{k} completions over all generations:")
    for i, p in enumerate(prefixes):
        comps = [f"{dictionary.decode_gram([t])[0]}:{int(c)}"
                 for t, c in zip(rows[i, 2:2 + k], rows[i, 2 + k:]) if c > 0]
        print(f"  {p!r} -> n={int(rows[i, 0])} total={int(rows[i, 1])}  "
              + " ".join(comps))


if __name__ == "__main__":
    main()
